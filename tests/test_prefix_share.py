"""Prefix-shared paged KV pool: refcounted PagePool, PrefixIndex, COW,
and shared-on/off bit-identical serving (DESIGN.md §Prefix sharing &
copy-on-write)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm
from repro.serve.engine import Engine, EngineConfig

TINY = ModelConfig(
    name="tiny-share", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)

TINY_WINDOW = dataclasses.replace(
    TINY, name="tiny-share-window", n_layers=3, window=8,
    local_global_ratio=2)

TINY_MLA = dataclasses.replace(
    TINY, name="tiny-share-mla", n_kv_heads=4, use_mla=True, kv_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)

TINY_HYBRID = dataclasses.replace(
    TINY, name="tiny-share-hybrid", family="hybrid", n_layers=4,
    ssm_d_state=8, ssm_conv=4, attn_period=2, attn_offset=1)


def _geometry(cfg, max_len=40, pt=8, n_layer0=12, n_layer1=24):
    pb = sm.kv_bytes_per_token(cfg) * pt
    return sm.derive_page_geometry(
        cfg, max_len, page_tokens=pt, max_slots=8,
        layer0_bytes=pb * n_layer0, layer1_bytes=pb * n_layer1)


def _shared_prompts(n, system_len=20, vocab=128, seed=3):
    rng = np.random.RandomState(seed)
    system = rng.randint(2, vocab, size=system_len).astype(np.int32)
    return system, [np.concatenate(
        [system, rng.randint(2, vocab,
                             size=int(rng.randint(2, 9))).astype(np.int32)])
        for _ in range(n)]


# ---------------------------------------------------- refcounted PagePool

def test_page_pool_share_and_release():
    pool = sm.PagePool(8)
    a = pool.alloc(3)
    pool.share(a[:2])                         # a second reader
    assert pool.in_use == 3 and pool.mapped == 5
    assert pool.mapped_high_water == 5
    assert pool.free(a) == [a[2]]             # shared pages stay resident
    assert pool.in_use == 2 and pool.mapped == 2
    assert sorted(pool.free(a[:2])) == sorted(a[:2])   # last reader frees
    assert pool.in_use == 0 and pool.mapped == 0


def test_page_pool_share_rejects_unmapped_and_foreign():
    pool = sm.PagePool(4)
    a = pool.alloc(1)
    pool.free(a)
    with pytest.raises(RuntimeError, match="unmapped"):
        pool.share(a)                         # refcount 0: nothing to share
    with pytest.raises(ValueError, match="outside"):
        pool.share([0])
    b = pool.alloc(1)
    pool.share(b)
    pool.free(b)
    with pytest.raises(RuntimeError, match="double free"):
        pool.free(b)
        pool.free(b)                          # refcount exhausted


# ------------------------------------------------------------ PrefixIndex

def test_prefix_index_chained_matching():
    idx = sm.PrefixIndex(page_tokens=4)
    prompt = np.arange(2, 16, dtype=np.int32)          # 14 tokens: 3 full
    idx.register(prompt, [5, 6, 7, 8])
    assert idx.match(prompt) == [5, 6, 7]
    # same second page but a different FIRST page: the chain must miss
    other = prompt.copy()
    other[0] += 1
    assert idx.match(other) == []
    # a shorter prompt matches only its own full pages
    assert idx.match(prompt[:9]) == [5, 6]
    idx.forget([6])
    assert idx.match(prompt) == [5]
    assert len(idx) == 2


def test_prefix_index_register_keeps_canonical():
    idx = sm.PrefixIndex(page_tokens=4)
    prompt = np.arange(2, 10, dtype=np.int32)
    assert idx.register(prompt, [3, 4]) == 2
    assert idx.register(prompt, [7, 8]) == 0    # duplicate content: skip
    assert idx.match(prompt) == [3, 4]


# --------------------------------------------------- scheduler admission

def test_sharing_admission_maps_shared_pages_and_counts():
    geom = _geometry(TINY)
    system, prompts = _shared_prompts(4)
    sch = sm.Scheduler(n_slots=4, pages=geom, prefix_share=True)
    for p in prompts:
        sch.submit(p, 8)
    plan = sch.plan_boundary(chunk_tokens=4, max_len=40)
    assert len(plan.admits) >= 2
    first, second = plan.admits[0][1], plan.admits[1][1]
    assert first.prefix_len == 0 and first.n_shared == 0
    # the system prompt holds 2 full pages of 8; the chain matches both
    assert second.prefix_len == 16 and second.n_shared == 2
    assert second.pages[:2] == first.pages[:2]          # aliased mappings
    assert sch.page_pool.refcount(first.pages[0]) >= 2
    assert sch.prefix_hits >= 1 and sch.prefix_misses == 1
    assert sch.shared_prefix_tokens >= 16
    stats = sch.stats()
    assert stats["prefix_sharing"] and stats["mapped_pages"] > \
        stats["pages_in_use"]


def test_cow_on_page_aligned_full_match():
    """A page-aligned prompt fully covered by the index: the match is
    capped at prompt_len - 1 and the frontier page is COW'd — mapped
    fresh and private, read from the canonical page."""
    geom = _geometry(TINY)
    prompt = np.arange(2, 18, dtype=np.int32)           # 16 = 2 full pages
    sch = sm.Scheduler(n_slots=4, pages=geom, prefix_share=True)
    a = sch.submit(prompt, 8)
    b = sch.submit(prompt.copy(), 8)
    sch.plan_boundary(chunk_tokens=4, max_len=40)
    assert a.prefix_len == 0
    assert b.prefix_len == 15 and b.n_shared == 1       # capped mid-page
    assert b.cow_src == a.pages[1]                      # canonical source
    assert b.pages[1] != a.pages[1]                     # private copy
    assert sch.page_pool.refcount(b.pages[1]) == 1      # never aliased
    assert sch.cow_copies == 1


def test_shared_pages_survive_other_readers_drain():
    """Freeing one reader must not reclaim a shared page; the last reader
    does, and the index entry falls with it."""
    geom = _geometry(TINY)
    _, prompts = _shared_prompts(3)
    sch = sm.Scheduler(n_slots=3, pages=geom, prefix_share=True)
    reqs = [sch.submit(p, 8) for p in prompts]
    sch.plan_boundary(chunk_tokens=4, max_len=40)
    shared_page = reqs[1].pages[0]
    assert sch.page_pool.refcount(shared_page) == 3
    for req in reqs:
        req.tokens.append(7)
    # drain the canonical owner first: page must stay for readers 2 and 3
    for slot in sorted(sch.active):
        if sch.active[slot].rid == reqs[0].rid:
            sch.complete(slot)
    assert sch.page_pool.refcount(shared_page) == 2
    assert shared_page not in sch.page_pool._free_set
    for slot in sorted(sch.active):
        sch.complete(slot)
    assert sch.page_pool.in_use == 0 and sch.page_pool.mapped == 0
    assert len(sch.prefix_index) == 0


def test_sharing_lifts_concurrent_residency():
    """Host-only replay of a shared-system-prompt stream: the same layer-0
    budget carries >= 1.5x the block-table mappings per physical page."""
    geom = _geometry(TINY, n_layer0=16)
    _, prompts = _shared_prompts(24, seed=5)
    sch = sm.Scheduler(n_slots=8, pages=geom, prefix_share=True)
    for p in prompts:
        sch.submit(p, 12)
    for _ in range(200):
        if not sch.has_work():
            break
        sch.plan_boundary(chunk_tokens=4, max_len=40)
        for slot in sorted(sch.active):
            req = sch.active[slot]
            take = min(4, req.max_new_tokens - len(req.tokens),
                       40 - req.cache_len)
            req.tokens.extend([7] * max(take, 0))
            if len(req.tokens) >= req.max_new_tokens or req.cache_len >= 40:
                sch.complete(slot)
    assert not sch.has_work()
    stats = sch.stats()
    assert stats["mapped_high_water"] >= 1.5 * stats["pages_high_water"]
    assert stats["prefix_hits"] >= 16


# ----------------------------------------------- engine: bit-exactness

def _serve(engine, prompts, gen, geom, share, n_slots=4):
    sch = sm.Scheduler(n_slots=n_slots, pages=geom, prefix_share=share)
    for p in prompts:
        sch.submit(p, gen)
    with jax.transfer_guard_device_to_host("disallow"):
        report = engine.serve(scheduler=sch)
    return {r.rid: r.tokens for r in report.requests}, report.stats


def test_shared_prefix_stream_bit_identical_32_requests():
    """32 requests sharing a long system prompt: sharing on == off
    bit-exactly (transfer-guard enforced), with a sharing request
    preempted and restored along the way."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=40, eos_token=1, sync_interval=4))
    _, prompts = _shared_prompts(32, system_len=20, seed=7)
    geom = _geometry(TINY, n_layer0=10, n_layer1=24)    # tight: must spill
    off, off_stats = _serve(eng, prompts, 12, geom, share=False)
    on, on_stats = _serve(eng, prompts, 12, geom, share=True)
    assert on == off
    assert on_stats["drained"] == 32
    assert on_stats["prefix_hits"] >= 20
    assert on_stats["shared_prefix_tokens"] >= 20 * 16
    # the tight layer-0 budget preempts sharing requests too: spilled
    # shared pages stay resident for their other readers and the restore
    # still reproduces the exact outputs
    assert on_stats["preemptions"] >= 1 and on_stats["restores"] >= 1
    assert on_stats["host_syncs"] == on_stats["chunks"]
    assert on_stats["mapped_high_water"] > on_stats["pages_high_water"]
    assert on_stats["pages_in_use"] == 0                # all pages freed


@pytest.mark.parametrize("cfg", [TINY_WINDOW, TINY_MLA],
                         ids=lambda c: c.name)
def test_shared_prefix_bit_identical_across_families(cfg):
    """Sliding-window and MLA (paged latent) admissions through the
    suffix-prefill path stay bit-identical with sharing off."""
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=40, eos_token=1, sync_interval=4))
    _, prompts = _shared_prompts(6, system_len=20, seed=11)
    # page-aligned fully-matched prompt: exercises the COW path too
    prompts.append(prompts[0][:16].copy())
    prompts.append(prompts[0][:16].copy())
    geom = _geometry(cfg)
    off, _ = _serve(eng, prompts, 10, geom, share=False)
    on, on_stats = _serve(eng, prompts, 10, geom, share=True)
    assert on == off
    assert on_stats["prefix_hits"] >= 5
    assert on_stats["cow_copies"] >= 1


def test_prefix_share_requires_attention_only_models():
    model = build_model(TINY_HYBRID)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=40, eos_token=1, sync_interval=4))
    sch = sm.Scheduler(n_slots=2, pages=_geometry(TINY_HYBRID),
                       prefix_share=True)
    sch.submit(np.arange(2, 12, dtype=np.int32), 4)
    with pytest.raises(ValueError, match="attention-only"):
        eng.serve(scheduler=sch)


def test_prefix_share_requires_paged_pool():
    with pytest.raises(ValueError, match="paged pool"):
        sm.Scheduler(n_slots=2, prefix_share=True)
