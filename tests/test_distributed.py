"""Multi-device behaviour (8 forced host devices) via subprocess — the test
process itself keeps the default single-device backend (see conftest)."""

import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))


@pytest.mark.slow
def test_distributed_checks():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist_checks.py")],
        capture_output=True, text=True, env=env, timeout=1200)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed checks failed"
    out = proc.stdout
    for name in ("mesh_device_count", "moe_ep_matches_dense",
                 "moe_ep_capacity_drops", "moe_partial_k_matches_dense",
                 "compressed_psum", "sharded_train_step", "pooled_decode",
                 "elastic_reshard_roundtrip"):
        assert f"PASS {name}" in out, f"missing: {name}"
    assert "ALL_DIST_CHECKS_PASSED" in out
