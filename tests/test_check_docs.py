"""tools/check_docs.py: the repo docs pass, broken references fail."""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_docs  # noqa: E402


def test_repo_docs_pass():
    """CI parity: every committed markdown reference resolves."""
    assert check_docs.main(check_docs.default_files()) == 0


def test_resolve_symbol():
    assert check_docs.resolve_symbol("repro.serve.scheduler.PagePool") == ""
    assert check_docs.resolve_symbol(
        "repro.serve.scheduler.PagePool.alloc") == ""
    assert "no attribute" in check_docs.resolve_symbol(
        "repro.serve.scheduler.SlabTable")
    assert check_docs.resolve_symbol("repro.no_such_module.Thing") != ""


def test_broken_symbol_reference_fails(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see `repro.serve.scheduler.SlabTable` for details\n")
    assert check_docs.main([str(bad)]) == 1


def test_unknown_cli_flag_fails(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("run `python -m repro.launch.serve --no-such-flag 1`\n")
    assert check_docs.main([str(bad)]) == 1


def test_flag_table_directive(tmp_path):
    good = tmp_path / "good.md"
    good.write_text(
        "<!-- check-docs: flags-for benchmarks.serve_bench -->\n\n"
        "| knob | meaning |\n|---|---|\n| `--prefix-share` | share |\n")
    assert check_docs.main([str(good)]) == 0
    bad = tmp_path / "bad.md"
    bad.write_text(
        "<!-- check-docs: flags-for benchmarks.serve_bench -->\n\n"
        "| knob | meaning |\n|---|---|\n| `--bogus-knob` | nope |\n")
    assert check_docs.main([str(bad)]) == 1


def test_line_continuations_are_joined(tmp_path):
    md = tmp_path / "cont.md"
    md.write_text("```bash\npython -m repro.launch.serve --stream 8 \\\n"
                  "    --no-such-flag\n```\n")
    assert check_docs.main([str(md)]) == 1


@pytest.mark.parametrize("ref", ["repro.serve.engine.Engine",
                                 "repro.models.api.Model.gather_row_paged",
                                 "repro.serve.scheduler.PrefixIndex"])
def test_documented_tentpole_symbols_exist(ref):
    assert check_docs.resolve_symbol(ref) == ""
