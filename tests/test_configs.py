"""Assigned-architecture configs: exact spec compliance + parameter counts."""

import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import build_model

#: (arch, n_layers, d_model, n_heads, n_kv, d_ff, vocab)
ASSIGNED = {
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    "yi-6b": (32, 4096, 32, 4, 11008, 64000),
    "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
    "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
    "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
    "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
    "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
}

#: published total parameter counts (approx), tolerance fraction
PARAM_TARGETS = {
    "yi-6b": (6.1e9, 0.12),
    "gemma3-27b": (27e9, 0.15),
    "qwen2.5-3b": (3.1e9, 0.15),
    "mistral-nemo-12b": (12.2e9, 0.12),
    # qwen2-vl-2b: published 2.2B INCLUDES the ~0.67B ViT; the assignment
    # stubs the vision frontend, so the backbone (Qwen2-1.5B, 1.54B) is built.
    "qwen2-vl-2b": (1.54e9, 0.10),
    "jamba-1.5-large-398b": (398e9, 0.12),
    "falcon-mamba-7b": (7.3e9, 0.15),
    "deepseek-v2-236b": (236e9, 0.10),
    "qwen3-moe-30b-a3b": (30.5e9, 0.12),
}

ACTIVE_TARGETS = {
    "deepseek-v2-236b": (21e9, 0.25),
    "qwen3-moe-30b-a3b": (3.3e9, 0.30),
    "jamba-1.5-large-398b": (94e9, 0.25),
}


def test_all_archs_registered():
    assert set(ARCH_IDS) == set(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_assigned_dims(arch):
    L, d, hq, hkv, ff, v = ASSIGNED[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.vocab_size == v
    if arch == "falcon-mamba-7b":
        assert cfg.family == "ssm"     # attention-free
        return
    assert cfg.n_heads == hq and cfg.n_kv_heads == hkv
    if arch == "deepseek-v2-236b":
        assert cfg.moe_d_ff == ff      # d_ff=1536 is the expert width
        assert cfg.use_mla and cfg.kv_lora_rank == 512
    elif arch == "qwen3-moe-30b-a3b":
        assert cfg.moe_d_ff == ff
    else:
        assert cfg.d_ff == ff


def test_moe_configs():
    ds = get_config("deepseek-v2-236b")
    assert (ds.n_experts, ds.top_k, ds.n_shared_experts) == (160, 6, 2)
    q3 = get_config("qwen3-moe-30b-a3b")
    assert (q3.n_experts, q3.top_k) == (128, 8)
    jb = get_config("jamba-1.5-large-398b")
    assert (jb.n_experts, jb.top_k) == (16, 2)


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-27b")
    kinds = [cfg.kind_for_layer(i) for i in range(12)]
    # 5 local : 1 global
    assert [k.window is None for k in kinds[:6]] == [False] * 5 + [True]
    assert kinds[0].window == 1024


def test_jamba_interleave_pattern():
    cfg = get_config("jamba-1.5-large-398b")
    kinds = [cfg.kind_for_layer(i) for i in range(8)]
    assert [k.attn for k in kinds] == (["mamba"] * 4 + ["gqa"] + ["mamba"] * 3)
    # MoE every 2nd layer
    assert [k.mlp for k in kinds] == ["mlp", "moe"] * 4


def test_falcon_mamba_attention_free():
    cfg = get_config("falcon-mamba-7b")
    assert all(cfg.kind_for_layer(i).attn == "mamba"
               for i in range(cfg.n_layers))
    assert cfg.ssm_d_state == 16


@pytest.mark.parametrize("arch", sorted(PARAM_TARGETS))
def test_param_counts_match_published(arch):
    target, tol = PARAM_TARGETS[arch]
    total, _ = get_config(arch).param_count()
    assert total == pytest.approx(target, rel=tol), \
        f"{arch}: {total/1e9:.2f}B vs published {target/1e9:.1f}B"


@pytest.mark.parametrize("arch", sorted(ACTIVE_TARGETS))
def test_active_param_counts(arch):
    target, tol = ACTIVE_TARGETS[arch]
    _, active = get_config(arch).param_count()
    assert active == pytest.approx(target, rel=tol), \
        f"{arch}: active {active/1e9:.2f}B vs published {target/1e9:.1f}B"


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_reduced_same_family(arch):
    """Smoke configs must exercise the same code paths as the full config."""
    full, red = get_config(arch), get_reduced(arch)
    assert full.family == red.family
    assert (full.n_experts > 0) == (red.n_experts > 0)
    assert full.use_mla == red.use_mla
    assert (full.local_global_ratio > 0) == (red.local_global_ratio > 0)
    assert (full.attn_period > 0) == (red.attn_period > 0)
    assert (full.n_encoder_layers > 0) == (red.n_encoder_layers > 0)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_layer_groups_cover_stack(arch):
    """The scan factorization must reproduce the layer stack exactly."""
    cfg = get_config(arch)
    groups = cfg.layer_groups()
    kinds = []
    for g in groups:
        kinds.extend(list(g.pattern) * g.n_repeat)
    assert kinds == [cfg.kind_for_layer(i) for i in range(cfg.n_layers)]
    # and be compact: unrolled pattern length far below depth for deep stacks
    unrolled = sum(len(g.pattern) for g in groups)
    if cfg.n_layers >= 24:
        assert unrolled <= max(8, cfg.n_layers // 3)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_long_500k_rule(arch):
    """long_500k runs iff the arch has a sub-quadratic path (DESIGN.md)."""
    model = build_model(get_config(arch))
    runnable = model.runnable_shapes()
    subq = arch in ("gemma3-27b", "jamba-1.5-large-398b", "falcon-mamba-7b")
    assert ("long_500k" in runnable) == subq
    assert {"train_4k", "prefill_32k", "decode_32k"} <= set(runnable)


def test_vocab_padding():
    cfg = get_config("seamless-m4t-medium")
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size
    assert cfg.padded_vocab - cfg.vocab_size < 256
