"""Control-plane fault tolerance: heartbeats, stragglers, restart policy,
elastic mesh sizing, failure injection."""

import pytest

from repro.train.fault_tolerance import (FailureInjector, HeartbeatMonitor,
                                         RestartPolicy, StragglerDetector,
                                         elastic_mesh_shape)


def test_heartbeat_detects_dead_host():
    mon = HeartbeatMonitor(timeout_s=10.0)
    mon.beat(0, now=100.0)
    mon.beat(1, now=100.0)
    mon.beat(0, now=150.0)
    assert mon.dead_hosts(now=155.0) == [1]
    assert not mon.healthy(now=155.0)
    mon.beat(1, now=156.0)
    assert mon.healthy(now=157.0)


def test_straggler_mad_detection():
    det = StragglerDetector(window=16, k_mad=5.0, min_samples=4)
    for step in range(8):
        for host in range(8):
            t = 1.0 + 0.01 * (step % 3)
            if host == 3:
                t *= 4.0               # persistent straggler
            det.record(host, t)
    assert det.stragglers() == [3]


def test_straggler_tolerates_jitter():
    det = StragglerDetector(window=16, k_mad=5.0, min_samples=4)
    import random
    rnd = random.Random(0)
    for step in range(16):
        for host in range(8):
            det.record(host, 1.0 + rnd.uniform(-0.05, 0.05))
    assert det.stragglers() == []


def test_straggler_needs_min_samples():
    det = StragglerDetector(min_samples=8)
    det.record(0, 1.0)
    det.record(1, 100.0)
    assert det.stragglers() == []


def test_restart_policy_backoff_and_abort():
    pol = RestartPolicy(max_restarts=3, backoff_base_s=1.0, backoff_cap_s=10.0)
    a0 = pol.next_action(0, [], 64)
    a1 = pol.next_action(1, [], 64)
    assert a0 == ("restart", 1.0)
    assert a1 == ("restart", 2.0)
    assert pol.next_action(3, [], 64)[0] == "abort"


def test_restart_policy_reslice_on_mass_failure():
    pol = RestartPolicy()
    action, _ = pol.next_action(0, dead_hosts=list(range(8)), n_hosts=64)
    assert action == "reslice"
    action, _ = pol.next_action(0, dead_hosts=[], n_hosts=64)
    assert action == "restart"


def test_elastic_mesh_shape():
    assert elastic_mesh_shape(256, 16) == (16, 16)
    assert elastic_mesh_shape(240, 16) == (15, 16)   # one host of 16 lost
    assert elastic_mesh_shape(17, 16) == (1, 16)
    with pytest.raises(ValueError):
        elastic_mesh_shape(8, 16)


def test_failure_injector():
    inj = FailureInjector(fail_at_steps=(5, 9), kind="crash")
    assert inj.check(4) is None
    assert inj.check(5) == "crash"
    assert inj.check(9) == "crash"
