"""Property test for speculative acceptance folding (ISSUE 7 satellite).

:func:`repro.serve.speculate.fold_acceptance` must agree with a literal
sequential simulator of the single-token decode loop on EVERY input, and
its invariants must hold unconditionally:

  * the accepted prefix is the longest exact match of drafts vs targets,
  * no token is emitted past the first rejection (emitted <= accepted+1),
  * the rolled-back ``cache_len`` is pre-verify + emitted — equivalently
    pre + accepted + 1 whenever no stop rule truncated the chunk,
  * emitted positions form a contiguous prefix of the verify chunk.
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.serve import speculate as sp

pytestmark = pytest.mark.properties

MAX_LEN = 16
EOS = 1


def _sequential(targets, drafts, dlen, done, n_gen, budget, cache_len):
    """Token-by-token replay of the engine's single-token stop rules."""
    toks, d, ng, cl = [], bool(done), int(n_gen), int(cache_len)
    if not d:
        for j in range(targets.shape[0]):
            t = int(targets[j])
            toks.append(t)
            ng += 1
            cl += 1
            if t == EOS or ng >= budget or cl >= MAX_LEN:
                d = True
                break
            if j < dlen and int(drafts[j]) == t:
                continue
            break
    return toks, d, ng, cl


@st.composite
def fold_case(draw):
    S = draw(st.integers(1, 5))
    k = draw(st.integers(1, 5))
    # tiny vocab (EOS included) so matches, rejections, and EOS all occur
    tok = st.integers(0, 6)
    targets = np.asarray(draw(st.lists(st.lists(tok, min_size=k + 1,
                                                max_size=k + 1),
                                       min_size=S, max_size=S)), np.int32)
    drafts = np.asarray(draw(st.lists(st.lists(tok, min_size=k,
                                               max_size=k),
                                      min_size=S, max_size=S)), np.int32)
    dlen = np.asarray(draw(st.lists(st.integers(0, k), min_size=S,
                                    max_size=S)), np.int32)
    done = np.asarray(draw(st.lists(st.booleans(), min_size=S,
                                    max_size=S)))
    n_gen = np.asarray(draw(st.lists(st.integers(0, 10), min_size=S,
                                     max_size=S)), np.int32)
    budget = np.asarray(draw(st.lists(st.integers(1, 12), min_size=S,
                                      max_size=S)), np.int32)
    cache_len = np.asarray(draw(st.lists(st.integers(0, MAX_LEN - 1),
                                         min_size=S, max_size=S)), np.int32)
    return targets, drafts, dlen, done, n_gen, budget, cache_len


@hypothesis.given(fold_case())
@hypothesis.settings(max_examples=120, deadline=None)
def test_fold_matches_sequential_replay(case):
    targets, drafts, dlen, done, n_gen, budget, cache_len = case
    S, k1 = targets.shape
    k = k1 - 1
    fold = sp.fold_acceptance(
        jnp.asarray(targets), jnp.asarray(drafts), jnp.asarray(dlen),
        done=jnp.asarray(done), n_gen=jnp.asarray(n_gen),
        budget=jnp.asarray(budget), cache_len=jnp.asarray(cache_len),
        max_len=MAX_LEN, eos_token=EOS)
    valid = np.asarray(fold.valid)
    emitted = np.asarray(fold.emitted)
    for s in range(S):
        toks, d, ng, cl = _sequential(targets[s], drafts[s], int(dlen[s]),
                                      done[s], n_gen[s], budget[s],
                                      cache_len[s])
        m = int(emitted[s])
        # the fold replays the sequential loop exactly
        assert m == len(toks)
        assert [int(targets[s, j]) for j in range(k1) if valid[s, j]] == toks
        assert int(np.asarray(fold.tok)[s]) == (toks[-1] if toks else EOS)
        assert bool(np.asarray(fold.done)[s]) == d
        assert int(np.asarray(fold.n_gen)[s]) == ng

        # invariants, stated independently of the simulator
        assert valid[s, :m].all() and not valid[s, m:].any()
        longest = 0
        while (longest < min(k, int(dlen[s]))
               and int(drafts[s, longest]) == int(targets[s, longest])):
            longest += 1
        assert m <= longest + 1          # nothing past the first rejection
        assert int(np.asarray(fold.cache_len)[s]) == int(cache_len[s]) + m
        stopped = any(int(targets[s, j]) == EOS
                      or int(n_gen[s]) + j + 1 >= int(budget[s])
                      or int(cache_len[s]) + j + 1 >= MAX_LEN
                      for j in range(m))
        if not done[s] and not stopped:
            # the pure-rejection case: rollback lands exactly at
            # pre-verify + accepted + 1
            assert m == longest + 1
