"""Sharding rules: divisibility fallbacks, parameter rules, stacked params."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


def test_fix_spec_drops_nondividing(mesh):
    # axis size 1 divides everything -> kept as-is
    assert shd.fix_spec_for(mesh, P("data", None), (4, 4)) == P("data", None)
    # unknown axis dropped
    assert shd.fix_spec_for(mesh, P("pod", None), (4, 4)) == P(None, None)


def test_fix_spec_nondivisible_replicates():
    """On a fake 4-way mesh shape, a dim of 6 cannot shard 4 ways."""
    class FakeMesh:
        axis_names = ("model",)
        shape = {"model": 4}
    assert shd._fix_spec(("model",), (6,), FakeMesh()) == (None,)
    assert shd._fix_spec(("model",), (8,), FakeMesh()) == ("model",)


def test_fix_spec_tuple_axes():
    class FakeMesh:
        axis_names = ("pod", "data")
        shape = {"pod": 2, "data": 4}
    assert shd._fix_spec((("pod", "data"),), (16,), FakeMesh()) == (("pod", "data"),)
    # greedy prefix: dim 4 shards over pod (2) and drops data (2*4=8 ∤ 4)
    assert shd._fix_spec((("pod", "data"),), (4,), FakeMesh()) == ("pod",)
    assert shd._fix_spec((("pod", "data"),), (3,), FakeMesh()) == (None,)


def test_fix_spec_pads_short_specs():
    class FakeMesh:
        axis_names = ("data",)
        shape = {"data": 2}
    assert shd._fix_spec(("data",), (4, 8, 8), FakeMesh()) == ("data", None, None)


def test_param_rules_attention(mesh):
    spec = shd.spec_for_param("groups/blocks/pos0/attn/wq", (64, 64), mesh)
    assert spec == P("data", "model")
    spec = shd.spec_for_param("groups/blocks/pos0/attn/wo", (64, 64), mesh)
    assert spec == P("model", "data")


def test_param_rules_stacked_scan_axis(mesh):
    """Stacked (n_repeat, ...) params get leading axes replicated."""
    spec = shd.spec_for_param("groups/blocks/pos0/mlp/w_gate", (8, 64, 128), mesh)
    assert spec == P(None, "data", "model")


def test_param_rules_moe_experts(mesh):
    spec = shd.spec_for_param("moe/we_gate", (16, 64, 128), mesh)
    assert spec == P("model", "data", None)
    spec = shd.spec_for_param("moe/we_down", (16, 128, 64), mesh)
    assert spec == P("model", None, "data")
    # stacked variant
    spec = shd.spec_for_param("groups/blocks/pos1/moe/we_up", (4, 16, 64, 128), mesh)
    assert spec == P(None, "model", "data", None)


def test_param_rules_embeddings(mesh):
    assert shd.spec_for_param("tok/embed", (512, 64), mesh) == P("model", "data")


def test_param_rules_default_replicated(mesh):
    assert shd.spec_for_param("final_norm/scale", (64,), mesh) == P(None)


def test_named_shardings_tree(mesh):
    params = {"attn": {"wq": jax.ShapeDtypeStruct((64, 64), jnp.float32)},
              "norm": {"scale": jax.ShapeDtypeStruct((64,), jnp.float32)}}
    sh = shd.named_shardings(params, mesh)
    assert sh["attn"]["wq"].spec == P("data", "model")
    assert sh["norm"]["scale"].spec == P(None)


class _Mesh2:
    """Fake 1x2 (data x model) mesh for spec-only tests."""
    axis_names = ("data", "model")
    shape = {"data": 1, "model": 2}


def test_model_axis_size_and_heads_divide():
    assert shd.model_axis_size(_Mesh2()) == 2
    assert shd.model_axis_size() == 1          # no ambient mesh
    assert shd.heads_divide(4, _Mesh2())
    assert not shd.heads_divide(3, _Mesh2())   # 3 heads, 2-way axis
    assert not shd.heads_divide(4)             # no ambient mesh


def test_cache_spec_head_axis_layouts():
    m = _Mesh2()
    # paged pool (n_pages, hkv, pt, hd): head axis shards, pages replicate
    assert shd.spec_for_cache("cache/layer0/k", (41, 2, 8, 16), m) \
        == P(None, "model", None, None)
    # stacked dense slab (n_repeat, B, hkv, max_len, hd)
    assert shd.spec_for_cache("groups/blocks/v", (3, 4, 2, 64, 16), m) \
        == P(None, None, "model", None, None)
    # non-dividing head count falls back to replication
    assert shd.spec_for_cache("k", (41, 3, 8, 16), m) \
        == P(None, None, None, None)


def test_cache_spec_state_leaves_replicate():
    m = _Mesh2()
    # MLA latent pages (n_pages, pt, lat): no head axis
    assert shd.spec_for_cache("cache/ckv", (41, 8, 16), m) == P(None, None, None)
    assert shd.spec_for_cache("cache/krope", (41, 8, 8), m) == P(None, None, None)
    # recurrent SSM state
    assert shd.spec_for_cache("cache/ssm", (4, 2, 16, 16), m) \
        == P(None, None, None, None)
    assert shd.spec_for_cache("cache/conv", (4, 2, 4, 16), m) \
        == P(None, None, None, None)


def test_cache_spec_only_matches_exact_leaf(mesh):
    # "wkv_a" ends in neither "k" nor "v" as a path COMPONENT: param rules
    # still apply, cache rules don't
    assert shd.spec_for_cache("attn/wkv_a", (64, 32), _Mesh2()) is None
    assert shd.spec_for_param("attn/wk", (64, 64), mesh) == P("data", "model")
    # and spec_for_param routes real cache leaves through the cache rule
    # instead of replicating them
    assert shd.spec_for_param("cache/k", (41, 2, 8, 16), _Mesh2()) \
        == P(None, "model", None, None)


def test_shard_noop_without_mesh():
    x = jnp.ones((8, 8))
    y = shd.shard(x, "data", None)
    assert (y == x).all()


def test_shard_inside_jit_with_mesh(mesh):
    @jax.jit
    def f(x):
        return shd.shard(x, "data", "model") * 2

    with shd.use_mesh(mesh):
        y = f(jnp.ones((4, 4)))
    assert (y == 2).all()
