"""Continuous-batching scheduler: slot table, admission, fairness, budget."""

import numpy as np
import pytest

from repro.core.target import get_target
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm

TINY = ModelConfig(
    name="tiny-sched", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)


def _prompt(rng, lo=2, hi=10):
    return rng.randint(2, 128, size=rng.randint(lo, hi)).astype(np.int32)


# ------------------------------------------------------------- slot table

def test_slot_table_allocate_release_reuse():
    t = sm.SlotTable(2)
    a = t.allocate(10)
    b = t.allocate(11)
    assert {a, b} == {0, 1} and not t.free_slots()
    with pytest.raises(RuntimeError):
        t.allocate(12)
    assert t.release(a) == 10
    c = t.allocate(12)
    assert c == a                       # freed slot is reused
    assert t.allocations[a] == 2        # reuse is counted
    with pytest.raises(RuntimeError):
        t.release(b) and t.release(b)   # double release of b

def test_slot_table_rejects_empty():
    with pytest.raises(ValueError):
        sm.SlotTable(0)


# -------------------------------------------------------------- admission

def test_admission_stops_when_pool_full():
    sch = sm.Scheduler(n_slots=2)
    rng = np.random.RandomState(0)
    reqs = [sch.submit(_prompt(rng), 4) for _ in range(5)]
    placed = sch.admit()
    assert len(placed) == 2             # pool full: only n_slots admitted
    assert len(sch.queue) == 3
    assert sch.admit() == []            # full pool admits nothing more
    # draining one slot opens exactly one seat, filled by the NEXT in queue
    slot0 = placed[0][0]
    sch.complete(slot0)
    placed2 = sch.admit()
    assert len(placed2) == 1 and placed2[0][1].rid == reqs[2].rid
    assert placed2[0][0] == slot0       # the freed slot was reused


def test_fcfs_fairness_under_mixed_stream():
    """FCFS admission must follow arrival order regardless of prompt length
    — long prompts are never starved by short ones."""
    sch = sm.Scheduler(n_slots=2)
    rng = np.random.RandomState(1)
    rids = [sch.submit(_prompt(rng, 2, 20), 4).rid for _ in range(10)]
    while sch.queue:
        for slot, _ in sch.admit():
            sch.complete(slot)
    sch.admit()
    assert sch.admit_order == rids      # arrival order == admission order


def test_shortest_policy_reorders():
    sch = sm.Scheduler(n_slots=1, policy="shortest")
    long = sch.submit(np.arange(2, 12, dtype=np.int32), 4)
    short = sch.submit(np.arange(2, 5, dtype=np.int32), 4)
    (slot, first), = sch.admit()
    assert first.rid == short.rid       # shortest prompt admitted first
    sch.complete(slot)
    (_, second), = sch.admit()
    assert second.rid == long.rid


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        sm.Scheduler(n_slots=1, policy="roulette")


# ----------------------------------------------------------- slot budget

def test_kv_bytes_per_token_counts_attention_layers():
    per_tok = sm.kv_bytes_per_token(TINY)
    # 2 layers x 2 (K+V) x n_kv_heads x head_dim x 2 bytes
    assert per_tok == 2 * 2 * 2 * 16 * 2
    assert sm.resident_bytes_per_slot(TINY) == 0   # no SSM layers


def test_pool_partition_uses_capacity_partition_formula():
    target = get_target("tpu-v5e")
    part = sm.pool_partition(target, fraction=0.5)
    hbm = target.hierarchy.level("hbm").capacity_bytes
    assert part.budget_bytes == hbm // 2
    assert part.n_buffers == 1          # KV rows are resident, not streamed x2
    # the budget formula is CapacityPartition.required_bytes, same as tiling
    assert part.required_bytes(100, 7) == 107


def test_pool_partition_mempool_uses_cluster_spm():
    target = get_target("mempool-3d-4mib")
    part = sm.pool_partition(target, fraction=1.0)
    assert part.budget_bytes == target.scratchpad_bytes


def test_derive_n_slots_scales_with_capacity_and_len():
    few = sm.derive_n_slots(TINY, 4096, target=get_target("mempool-2d-1mib"),
                            max_slots=10_000)
    more = sm.derive_n_slots(TINY, 4096, target=get_target("mempool-2d-8mib"),
                             max_slots=10_000)
    assert more > few                   # bigger pool -> more resident slots
    shorter = sm.derive_n_slots(TINY, 1024,
                                target=get_target("mempool-2d-1mib"),
                                max_slots=10_000)
    assert shorter > few                # shorter slots -> more of them
    assert sm.derive_n_slots(TINY, 10**9,
                             target=get_target("mempool-2d-1mib")) == 1


# ------------------------------------------------------- two-tier pool

def test_pool_tiers_mirror_the_die_split():
    """3D-flow targets get a full stacked layer (the bonded memory die);
    2D and TPU targets get a half-layer spill budget."""
    t3d = sm.pool_tiers(get_target("mempool-3d-4mib"), fraction=1.0)
    assert t3d.layer1.budget_bytes == t3d.layer0.budget_bytes
    t2d = sm.pool_tiers(get_target("mempool-2d-4mib"), fraction=1.0)
    assert t2d.layer1.budget_bytes == t2d.layer0.budget_bytes // 2
    tpu = sm.pool_tiers(get_target("tpu-v5e"), fraction=0.5)
    assert tpu.layer0.budget_bytes == tpu.layer0.capacity_bytes // 2
    assert tpu.layer1.budget_bytes == tpu.layer0.budget_bytes // 2


def test_derive_page_geometry_from_target_budget():
    geom = sm.derive_page_geometry(TINY, 1024, page_tokens=16,
                                   target=get_target("mempool-3d-1mib"),
                                   max_slots=8)
    assert geom.page_tokens == 16
    assert geom.max_pages_per_slot == 64
    assert geom.depth == 1024
    assert geom.page_bytes == sm.kv_bytes_per_token(TINY) * 16
    # capped at max_slots full-depth sequences, never below one sequence
    assert geom.max_pages_per_slot <= geom.n_data_pages <= 8 * 64
    assert geom.pages_for(1) == 1 and geom.pages_for(17) == 2


def test_for_model_paged_carries_geometry_and_more_slots():
    dense = sm.Scheduler.for_model(TINY, 256,
                                   target=get_target("mempool-2d-1mib"),
                                   max_slots=64)
    paged = sm.Scheduler.for_model(TINY, 256,
                                   target=get_target("mempool-2d-1mib"),
                                   max_slots=64, paged=True, page_tokens=16)
    assert dense.pages is None and paged.pages is not None
    assert paged.page_pool.n_free == paged.pages.n_data_pages
    # pages, not slabs: same budget carries more resident sequences
    assert paged.n_slots >= dense.n_slots
    assert paged.stats()["paged"] and not dense.stats()["paged"]


def test_stats_latency_and_spill_counters():
    sch = sm.Scheduler(n_slots=1)
    a = sch.submit(np.arange(2, 8, dtype=np.int32), 4, submit_step=0)
    sch.admit()
    a.admit_step = 8
    sch.complete(0)
    a.finish_step = 24
    s = sch.stats()
    assert s["ttft_steps"] == [8]
    assert s["e2e_steps"] == [24]
    assert s["preemptions"] == 0 and s["spilled_pages"] == 0
