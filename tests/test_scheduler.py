"""Continuous-batching scheduler: slot table, admission, fairness, budget."""

import numpy as np
import pytest

from repro.core.target import get_target
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm

TINY = ModelConfig(
    name="tiny-sched", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)


def _prompt(rng, lo=2, hi=10):
    return rng.randint(2, 128, size=rng.randint(lo, hi)).astype(np.int32)


# ------------------------------------------------------------- slot table

def test_slot_table_allocate_release_reuse():
    t = sm.SlotTable(2)
    a = t.allocate(10)
    b = t.allocate(11)
    assert {a, b} == {0, 1} and not t.free_slots()
    with pytest.raises(RuntimeError):
        t.allocate(12)
    assert t.release(a) == 10
    c = t.allocate(12)
    assert c == a                       # freed slot is reused
    assert t.allocations[a] == 2        # reuse is counted
    with pytest.raises(RuntimeError):
        t.release(b) and t.release(b)   # double release of b

def test_slot_table_rejects_empty():
    with pytest.raises(ValueError):
        sm.SlotTable(0)


# -------------------------------------------------------------- admission

def test_admission_stops_when_pool_full():
    sch = sm.Scheduler(n_slots=2)
    rng = np.random.RandomState(0)
    reqs = [sch.submit(_prompt(rng), 4) for _ in range(5)]
    placed = sch.admit()
    assert len(placed) == 2             # pool full: only n_slots admitted
    assert len(sch.queue) == 3
    assert sch.admit() == []            # full pool admits nothing more
    # draining one slot opens exactly one seat, filled by the NEXT in queue
    slot0 = placed[0][0]
    sch.complete(slot0)
    placed2 = sch.admit()
    assert len(placed2) == 1 and placed2[0][1].rid == reqs[2].rid
    assert placed2[0][0] == slot0       # the freed slot was reused


def test_fcfs_fairness_under_mixed_stream():
    """FCFS admission must follow arrival order regardless of prompt length
    — long prompts are never starved by short ones."""
    sch = sm.Scheduler(n_slots=2)
    rng = np.random.RandomState(1)
    rids = [sch.submit(_prompt(rng, 2, 20), 4).rid for _ in range(10)]
    while sch.queue:
        for slot, _ in sch.admit():
            sch.complete(slot)
    sch.admit()
    assert sch.admit_order == rids      # arrival order == admission order


def test_shortest_policy_reorders():
    sch = sm.Scheduler(n_slots=1, policy="shortest")
    long = sch.submit(np.arange(2, 12, dtype=np.int32), 4)
    short = sch.submit(np.arange(2, 5, dtype=np.int32), 4)
    (slot, first), = sch.admit()
    assert first.rid == short.rid       # shortest prompt admitted first
    sch.complete(slot)
    (_, second), = sch.admit()
    assert second.rid == long.rid


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        sm.Scheduler(n_slots=1, policy="roulette")


# ----------------------------------------------------------- slot budget

def test_kv_bytes_per_token_counts_attention_layers():
    per_tok = sm.kv_bytes_per_token(TINY)
    # 2 layers x 2 (K+V) x n_kv_heads x head_dim x 2 bytes
    assert per_tok == 2 * 2 * 2 * 16 * 2
    assert sm.resident_bytes_per_slot(TINY) == 0   # no SSM layers


def test_pool_partition_uses_capacity_partition_formula():
    target = get_target("tpu-v5e")
    part = sm.pool_partition(target, fraction=0.5)
    hbm = target.hierarchy.level("hbm").capacity_bytes
    assert part.budget_bytes == hbm // 2
    assert part.n_buffers == 1          # KV rows are resident, not streamed x2
    # the budget formula is CapacityPartition.required_bytes, same as tiling
    assert part.required_bytes(100, 7) == 107


def test_pool_partition_mempool_uses_cluster_spm():
    target = get_target("mempool-3d-4mib")
    part = sm.pool_partition(target, fraction=1.0)
    assert part.budget_bytes == target.scratchpad_bytes


def test_derive_n_slots_scales_with_capacity_and_len():
    few = sm.derive_n_slots(TINY, 4096, target=get_target("mempool-2d-1mib"),
                            max_slots=10_000)
    more = sm.derive_n_slots(TINY, 4096, target=get_target("mempool-2d-8mib"),
                             max_slots=10_000)
    assert more > few                   # bigger pool -> more resident slots
    shorter = sm.derive_n_slots(TINY, 1024,
                                target=get_target("mempool-2d-1mib"),
                                max_slots=10_000)
    assert shorter > few                # shorter slots -> more of them
    assert sm.derive_n_slots(TINY, 10**9,
                             target=get_target("mempool-2d-1mib")) == 1
